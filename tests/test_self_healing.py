"""Self-healing SAGe store (ISSUE 8): parity, reconstruction, scrub, repair.

Acceptance contract: a parity container is bit-identical to its plain
sibling on the clean path (all 3 formats x both decode paths) and
pre-parity containers stay readable unchanged; single-extent at-rest
damage is reconstructed IN FLIGHT from parity and repaired durably by
``store.repair``/the scrubber; damage beyond the parity budget still
raises the typed error and quarantines; the migrate CLI grows
``--add-parity``/``--repair``.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import SageStore, Scrubber
from repro.core.encoder import SageEncoder
from repro.core.errors import IntegrityError
from repro.core.layout import SageContainerV2, container_version, write_v2
from repro.core.parity import (
    GF_EXP,
    encode_parity,
    gf_mul_row,
    n_shards,
    parity_coeff,
    recover_erasures,
)
from repro.genomics.synth import make_reference, sample_read_set
from repro.testing.faults import corrupt_extent, corrupt_extents, corrupt_parity

GB = 2  # store residency group size (!= the container's parity_group)


@pytest.fixture(scope="module")
def ds():
    ref = make_reference(20_000, seed=80)
    rs = sample_read_set(ref, "illumina", depth=3, seed=81)
    return SageEncoder(ref, token_target=2048).encode(rs)


@pytest.fixture(scope="module")
def plain_path(ds, tmp_path_factory):
    p = tmp_path_factory.mktemp("heal") / "plain.sage2"
    write_v2(ds, p, align=512)
    return str(p)


@pytest.fixture()
def parity_path(ds, tmp_path):
    p = tmp_path / "parity.sage2"
    write_v2(ds, p, align=512, parity="xor", parity_group=4)
    return str(p)


@pytest.fixture()
def rs_path(ds, tmp_path):
    p = tmp_path / "rs.sage2"
    write_v2(ds, p, align=512, parity="rs", parity_group=4, parity_shards=2)
    return str(p)


def store_over(path, **kw):
    kw.setdefault("group_blocks", GB)
    store = SageStore(**kw)
    store.register("ds", path)
    return store


# ------------------------------------------------------------ GF(256) maths
def test_xor_scheme_is_single_shard_gf_identity():
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, size=(5, 64), dtype=np.uint8)
    enc = encode_parity(data, 1)
    assert enc.shape == (1, 64)
    want = np.zeros(64, np.uint8)
    for row in data:
        want ^= row
    np.testing.assert_array_equal(enc[0], want)
    assert parity_coeff(0, 3) == 1  # shard 0 is plain XOR: all coeffs 1


def test_recover_every_single_and_double_erasure():
    rng = np.random.default_rng(1)
    k, m, L = 5, 2, 48
    data = rng.integers(0, 256, size=(k, L), dtype=np.uint8)
    parity = {j: enc for j, enc in enumerate(encode_parity(data, m))}
    for a in range(k):
        for b in range(a + 1, k):
            known = {i: data[i] for i in range(k) if i not in (a, b)}
            got = recover_erasures(known, [a, b], parity, L)
            np.testing.assert_array_equal(got[a], data[a])
            np.testing.assert_array_equal(got[b], data[b])
    # single erasure with only one intact shard also recovers
    got = recover_erasures(
        {i: data[i] for i in range(1, k)}, [0], {1: parity[1]}, L
    )
    np.testing.assert_array_equal(got[0], data[0])


def test_erasures_beyond_intact_parity_raise():
    data = np.arange(4 * 16, dtype=np.uint8).reshape(4, 16)
    parity = {0: encode_parity(data, 1)[0]}
    with pytest.raises(ValueError, match="erasure"):
        recover_erasures({2: data[2], 3: data[3]}, [0, 1], parity, 16)


def test_parity_parameter_validation():
    assert n_shards("xor", 7) == 1  # xor ignores the shard count
    assert n_shards("rs", 3) == 3
    with pytest.raises(ValueError):
        n_shards("raid7", 1)
    with pytest.raises(ValueError):
        n_shards("rs", 0)
    with pytest.raises(ValueError):
        encode_parity(np.zeros((256, 4), np.uint8), 1)  # k > MAX_GROUP
    assert GF_EXP[255] == GF_EXP[0]  # the exp table wraps at 255
    row = np.array([0, 1, 7, 255], np.uint8)
    np.testing.assert_array_equal(gf_mul_row(row, 1), row)
    np.testing.assert_array_equal(gf_mul_row(row, 0), np.zeros(4, np.uint8))


# ------------------------------------------------- clean-path bit identity
@pytest.mark.parametrize("fmt", ["2bit", "onehot", "kmer"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_parity_container_clean_path_bit_identical(
    ds, plain_path, parity_path, rs_path, fmt, use_pallas
):
    """The parity section is invisible on the clean read path: xor and rs
    containers decode bit-identically to the plain sibling for every
    format on both decode paths."""
    want = store_over(plain_path).session(use_pallas=use_pallas).read(
        "ds", None, fmt=fmt, kmer_k=4
    )
    for p in (parity_path, rs_path):
        got = store_over(p).session(use_pallas=use_pallas).read(
            "ds", None, fmt=fmt, kmer_k=4
        )
        np.testing.assert_array_equal(
            np.asarray(want["tokens"]), np.asarray(got["tokens"])
        )


def test_parity_sections_equal_plain_sections(ds, plain_path, parity_path):
    assert SageContainerV2.open(parity_path).to_sage_file().diff(ds) == []
    assert SageContainerV2.open(plain_path).to_sage_file().diff(
        SageContainerV2.open(parity_path).to_sage_file()
    ) == []


def test_container_version_reports_parity(plain_path, parity_path, rs_path):
    assert container_version(parity_path) == 2  # magic unchanged
    for path, scheme, m in (
        (plain_path, None, 0), (parity_path, "xor", 1), (rs_path, "rs", 2),
    ):
        d = container_version(path, detail=True)
        assert d["version"] == 2 and d["integrity"]
        assert d["parity"] == scheme and d["parity_shards"] == m


def test_parity_requires_integrity_layout(ds, tmp_path):
    with pytest.raises(ValueError, match="integrity"):
        write_v2(ds, tmp_path / "x.sage2", integrity=False, parity="xor")
    with pytest.raises(ValueError, match="scheme"):
        write_v2(ds, tmp_path / "y.sage2", parity="raid0")


# -------------------------------------------------- in-flight reconstruction
def test_inflight_reconstruction_serves_bit_identical(plain_path, parity_path):
    undo = corrupt_extent(parity_path, 1, byte=7, bit=5)
    store = store_over(parity_path)
    got = store.session().read("ds", None)
    want = store_over(plain_path).session().read("ds", None)
    np.testing.assert_array_equal(
        np.asarray(want["tokens"]), np.asarray(got["tokens"])
    )
    io = store.io_stats
    assert io["reconstructions"] >= 1 and io["parity_reads"] >= 1
    assert io["reconstruction_failures"] == 0
    assert store.health("ds")["ok"]  # healed in flight, never quarantined
    # the MEDIUM is still damaged: in-flight healing serves, repair rewrites
    assert SageContainerV2.open(parity_path).verify_blocks() == [1]
    undo()


def test_rs_container_survives_double_erasure(plain_path, rs_path):
    corrupt_extents(rs_path, [4, 6], byte=3, bit=1)  # both in parity group 1
    store = store_over(rs_path)
    got = store.session().read("ds", None)
    want = store_over(plain_path).session().read("ds", None)
    np.testing.assert_array_equal(
        np.asarray(want["tokens"]), np.asarray(got["tokens"])
    )
    assert store.io_stats["reconstructions"] >= 2


def test_damage_beyond_parity_budget_raises_typed(parity_path):
    corrupt_extents(parity_path, [0, 2], byte=3, bit=1)  # xor: 1-shard budget
    store = store_over(parity_path)
    with pytest.raises(IntegrityError) as ei:
        store.session().read("ds", None)
    assert set(ei.value.blocks or ()) >= {0, 2}
    assert store.io_stats["reconstruction_failures"] >= 1
    assert not store.health("ds")["ok"]


def test_damaged_parity_is_never_used_for_reconstruction(parity_path):
    """Data AND the group's only parity shard damaged: reconstruction must
    refuse (the shard fails ITS checksum) rather than decode garbage."""
    corrupt_extent(parity_path, 1, byte=2, bit=4)
    corrupt_parity(parity_path, group=0, shard=0, byte=5, bit=3)
    store = store_over(parity_path)
    with pytest.raises(IntegrityError):
        store.session().read("ds", None)
    assert store.io_stats["reconstruction_failures"] >= 1


# ------------------------------------------------------- durable repair
def test_scan_rebuild_rewrite_parity_shard(parity_path):
    undo = corrupt_parity(parity_path, group=1, shard=0, byte=9, bit=6)
    c = SageContainerV2.open(parity_path)
    assert c.verify_blocks() == []  # data is fine
    bad = c.verify_parity()
    assert bad == [1]  # group 1, shard 0 -> flat index 1*1+0
    fixed = c.rebuild_parity(bad)
    c.rewrite_extents({}, fixed)
    fresh = SageContainerV2.open(parity_path)
    assert fresh.verify_parity() == []
    undo()  # undoing AFTER the rewrite re-flips the (now correct) byte
    assert SageContainerV2.open(parity_path).verify_parity() == [1]


def test_rewrite_refuses_bytes_not_matching_stored_crc(parity_path):
    c = SageContainerV2.open(parity_path)
    L = int(c.extents[0, 1])  # stored (codec) extent length, not decoded
    with pytest.raises(IntegrityError, match="stored CRC"):
        c.rewrite_extents({0: np.full(L, 0xAB, np.uint8)})


def test_store_repair_full_sweep_heals_the_medium(plain_path, parity_path):
    corrupt_extent(parity_path, 3, byte=11, bit=2)
    store = store_over(parity_path)
    summary = store.repair("ds")  # nothing quarantined -> full scan
    assert summary["damaged_blocks"] == [3]
    assert summary["repaired_blocks"] == [3]
    assert summary["scanned_blocks"] == store.n_blocks("ds")
    fresh = SageContainerV2.open(parity_path)
    assert fresh.verify_blocks() == [] and fresh.verify_parity() == []
    got = store.session().read("ds", None)
    want = store_over(plain_path).session().read("ds", None)
    np.testing.assert_array_equal(
        np.asarray(want["tokens"]), np.asarray(got["tokens"])
    )


def test_store_repair_lifts_quarantine_only_after_reverify(parity_path):
    corrupt_extent(parity_path, 2, byte=4, bit=7)
    store = store_over(parity_path)
    store.quarantine("ds", 1)  # block 2 // GB -> store group 1
    with pytest.raises(IntegrityError, match="quarantined"):
        store.session().read("ds", (2, 3))
    summary = store.repair("ds")  # scope = the quarantined set
    assert summary["lifted_groups"] == [1]
    assert store.health("ds")["ok"]
    store.session().read("ds", (2, 3))  # serves again, no clear_quarantine


def test_store_repair_validation(plain_path, ds):
    store = store_over(plain_path)
    with pytest.raises(ValueError, match="not registered"):
        store.repair("nope")
    with pytest.raises(ValueError, match="out of range"):
        store.repair("ds", group=999)
    eager = SageStore()
    eager.register("mem", ds)
    with pytest.raises(ValueError, match="v2"):
        eager.repair("mem")


def test_store_repair_without_parity_quarantines_and_raises(plain_path, tmp_path):
    import shutil

    p = str(tmp_path / "copy.sage2")
    shutil.copy(plain_path, p)
    corrupt_extent(p, 0, byte=6, bit=1)
    store = store_over(p)
    with pytest.raises(IntegrityError, match="no parity"):
        store.repair("ds", group=0)
    assert store.health("ds")["quarantined_groups"] == (0,)


# ------------------------------------------------------------- the scrubber
def test_scrub_clean_sweep_reports_in_health(parity_path):
    store = store_over(parity_path)
    scrub = Scrubber(store, chunk_blocks=4)
    r = scrub.run_once()
    assert r["complete"] and r["findings"] == []
    assert r["blocks_scanned"] == store.n_blocks("ds")
    h = store.health("ds")
    assert h["ok"] and h["scrub"]["sweeps_completed"] == 1
    assert h["scrub"]["findings"] == []
    assert store.health()["ds"]["scrub"]["n_blocks"] == store.n_blocks("ds")


def test_scrub_finds_and_repairs_damage(parity_path):
    corrupt_extent(parity_path, 5, byte=8, bit=3)
    store = store_over(parity_path)
    scrub = Scrubber(store, chunk_blocks=4)
    r = scrub.run_once()
    assert r["complete"]
    assert [f["action"] for f in r["findings"]] == ["repaired"]
    assert r["findings"][0]["blocks"] == (5,)
    fresh = SageContainerV2.open(parity_path)
    assert fresh.verify_blocks() == []
    assert store.health("ds")["ok"]
    assert store.health("ds")["scrub"]["findings"] == r["findings"]


def test_scrub_auto_repair_off_quarantines_for_later(parity_path):
    corrupt_extent(parity_path, 5, byte=8, bit=3)
    store = store_over(parity_path)
    scrub = Scrubber(store, auto_repair=False)
    r = scrub.run_once()
    assert [f["action"] for f in r["findings"]] == ["found"]
    assert store.health("ds")["quarantined_groups"] == (2,)  # 5 // GB
    # deferred repair (the batcher's on-demand path) heals and lifts
    store.repair("ds", group=2)
    assert store.health("ds")["ok"]
    assert SageContainerV2.open(parity_path).verify_blocks() == []


def test_scrub_unrecoverable_damage_quarantines(parity_path):
    corrupt_extents(parity_path, [0, 2], byte=8, bit=3)  # > xor budget
    store = store_over(parity_path)
    scrub = Scrubber(store)
    r = scrub.run_once()
    acts = {f["action"] for f in r["findings"]}
    assert acts == {"quarantined"}
    assert not store.health("ds")["ok"]
    assert 0 in store.health("ds")["quarantined_groups"]


def test_damage_landing_mid_sweep_is_caught_next_chunk(parity_path):
    """Corruption that lands AHEAD of the cursor during a sweep is found
    by the same pass; the cursor survives the partial run."""
    store = store_over(parity_path)
    scrub = Scrubber(store, chunk_blocks=2)
    r = scrub.run_once(max_blocks=2)  # partial pass: cursor at block 2
    assert not r["complete"] and store.health("ds")["scrub"]["cursor"] == 2
    corrupt_extent(parity_path, 6, byte=8, bit=3)  # ahead of the cursor
    r2 = scrub.run_once()  # resumes at 2, reaches the damage
    assert r2["complete"]
    assert [f["action"] for f in r2["findings"]] == ["repaired"]
    assert SageContainerV2.open(parity_path).verify_blocks() == []


def test_scrub_rate_limit_bounds_bandwidth(parity_path):
    store = store_over(parity_path)
    nbytes = store.n_blocks("ds") * SageContainerV2.open(parity_path).stride_nbytes
    rate = nbytes / 0.2  # a full sweep must take >= ~0.2s
    scrub = Scrubber(store, rate_bps=rate, chunk_blocks=2)
    r = scrub.run_once()
    assert r["complete"]
    assert r["elapsed_s"] >= 0.9 * (r["bytes_scanned"] / rate)
    assert r["effective_bps"] <= 1.2 * rate


def test_scrub_background_thread_pause_resume_stop(parity_path):
    import time

    store = store_over(parity_path)
    scrub = Scrubber(store, interval_s=0.01)
    scrub.start()
    with pytest.raises(RuntimeError, match="already running"):
        scrub.start()
    deadline = time.monotonic() + 10
    while scrub.status()["sweeps_completed"] < 2:
        assert time.monotonic() < deadline, "background sweeps never ran"
        time.sleep(0.01)
    scrub.pause()
    assert scrub.paused
    scrub.resume()
    assert not scrub.paused
    scrub.stop(join=True)
    assert not scrub.running
    scrub.stop()  # idempotent
    st = scrub.status()
    assert st["sweeps_completed"] >= 2 and st["sweep_errors"] == 0
    assert st["blocks_scanned"] >= 2 * store.n_blocks("ds")


def test_scrub_parameter_validation(parity_path):
    store = store_over(parity_path)
    with pytest.raises(ValueError):
        Scrubber(store, rate_bps=0)
    with pytest.raises(ValueError):
        Scrubber(store, chunk_blocks=0)
    with pytest.raises(ValueError):
        Scrubber(store, interval_s=-1)


# ------------------------------------------------------------- migrate CLI
def migrate(*args):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "tools/migrate_container.py", *args],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )


def test_migrate_add_parity_then_repair_in_place(plain_path, tmp_path):
    prot = str(tmp_path / "prot.sage2")
    r = migrate(plain_path, prot, "--add-parity", "rs",
                "--parity-group", "4", "--parity-shards", "2", "--verify")
    assert r.returncode == 0, r.stderr
    assert "parity rs x2/4" in r.stdout and "bit-identical" in r.stdout
    d = container_version(prot, detail=True)
    assert d["parity"] == "rs" and d["parity_shards"] == 2
    # clean container: --repair is a no-op that says so
    r = migrate(prot, "--repair")
    assert r.returncode == 0 and "nothing to repair" in r.stdout
    # two damaged extents in one group: within the rs budget, healed
    corrupt_extents(prot, [0, 2], byte=5, bit=4)
    r = migrate(prot, "--repair")
    assert r.returncode == 0, r.stderr
    assert "repaired and re-verified clean" in r.stdout
    fresh = SageContainerV2.open(prot)
    assert fresh.verify_blocks() == [] and fresh.verify_parity() == []
    # three damaged extents: beyond the budget, non-zero exit
    corrupt_extents(prot, [0, 1, 2], byte=5, bit=4)
    r = migrate(prot, "--repair")
    assert r.returncode == 1 and "REPAIR FAILED" in r.stderr


def test_migrate_repair_rejects_bad_flag_combos(plain_path, tmp_path):
    r = migrate(plain_path, str(tmp_path / "x"), "--repair")
    assert r.returncode != 0 and "in place" in r.stderr
    r = migrate(plain_path, str(tmp_path / "x.sage2"),
                "--add-parity", "--to-v1")
    assert r.returncode != 0
    r = migrate(plain_path)  # dst required without --repair
    assert r.returncode != 0
