"""SageServer frontend: output parity, streaming, multi-tenant residency,
engine fixes, and ``prompts_from_store`` edge cases.

The acceptance contract: everything the server returns for the read path
is bit-identical to a direct ``session.read`` of the same blocks; streams
deliver every chunk in order; the session pool keeps ONE device residency
across tenants; engines no longer share a ``ServeConfig``; and the prompt
feed handles over-asking, zero-k-mer ranges, and truncation consistently
with the engine's slot layout.
"""

import threading

import numpy as np
import pytest

import jax

from repro.core import SageStore
from repro.data.pipeline import SageTokenPipeline
from repro.genomics.synth import ReadSet, make_reference, sample_read_set
from repro.serving import (
    RequestState,
    SageServer,
    ServeConfig,
    ServingEngine,
    SessionPool,
    prompts_from_store,
)


@pytest.fixture(scope="module")
def pool():
    ref = make_reference(24_000, seed=70)
    rs = sample_read_set(ref, "illumina", depth=3, seed=71)
    p = SessionPool(max_prepared=4)
    p.write("ds", rs, ref, token_target=4096)
    return p


@pytest.fixture(scope="module")
def v2_pool(tmp_path_factory):
    """A lazy out-of-core dataset: block-granular residency under serving."""
    ref = make_reference(24_000, seed=72)
    rs = sample_read_set(ref, "illumina", depth=3, seed=73)
    path = tmp_path_factory.mktemp("serve_v2") / "ds.sage2"
    p = SessionPool(max_prepared=4, group_blocks=2)
    p.write("ds", rs, ref, token_target=4096, layout="v2", path=path)
    return p


@pytest.fixture(scope="module")
def tiny_engine():
    from repro.configs import get_arch
    from repro.models import lm

    cfg = get_arch("qwen2-1.5b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return ServingEngine(cfg, params, ServeConfig(max_prompt=16, max_new=8))


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("fmt,k", [("2bit", None), ("kmer", 4), ("onehot", None)])
def test_server_read_parity_with_direct_session(pool, fmt, k):
    srv = SageServer(pool)
    h = srv.read("ds", (0, 3), fmt=fmt, kmer_k=k)
    srv.run_until_idle()
    direct = pool.session().read("ds", (0, 3), fmt, kmer_k=k)
    got = h.result()["data"]
    for key, v in direct.items():
        if key == "block_ids":
            continue
        np.testing.assert_array_equal(np.asarray(got[key]), np.asarray(v), err_msg=key)


def test_fused_batch_parity_each_request_gets_its_own_slice(pool):
    """Overlapping concurrent requests fuse into one decode; every tenant
    still receives exactly its own blocks."""
    srv = SageServer(pool)
    ranges = [(0, 2), (1, 4), (2, 3), (0, 4)]
    hs = [srv.read("ds", r) for r in ranges]
    srv.run_until_idle()
    assert srv.batcher.stats["fused_reads"] == 1  # one decode for all four
    sess = pool.session()
    for h, r in zip(hs, ranges):
        direct = sess.read("ds", r)
        got = h.result()
        np.testing.assert_array_equal(got["block_ids"], np.arange(*r))
        np.testing.assert_array_equal(
            np.asarray(got["data"]["tokens"]), np.asarray(direct["tokens"])
        )


def test_isp_stream_chunks_match_direct_reads(pool):
    srv = SageServer(pool)
    h = srv.stream("ds", (0, 4), blocks_per_fetch=2, fmt="kmer", kmer_k=4)
    srv.run_until_idle()
    chunks = list(h.chunks(timeout=0))
    assert [c["fetch"] for c in chunks] == [0, 1]
    sess = pool.session()
    for c in chunks:
        direct = sess.read("ds", c["block_ids"], "kmer", kmer_k=4)
        np.testing.assert_array_equal(
            np.asarray(c["data"]["kmer"]), np.asarray(direct["kmer"])
        )


def test_consensus_parity(pool):
    srv = SageServer(pool)
    h = srv.consensus("ds", (1, 4))
    srv.run_until_idle()
    wins, starts = pool.store.consensus_windows("ds", np.arange(1, 4))
    out = h.result()
    np.testing.assert_array_equal(out["windows"], wins)
    np.testing.assert_array_equal(out["starts"], starts)


def test_v2_store_served_block_granular(v2_pool):
    """Out-of-core datasets serve through the same frontend: residency is
    block-group granular and reads touch only covering groups."""
    store = v2_pool.store
    store.evict()
    store.reset_io_stats()
    srv = SageServer(v2_pool)
    h = srv.read("ds", (0, 2))
    srv.run_until_idle()
    direct = v2_pool.session().read("ds", (0, 2))
    np.testing.assert_array_equal(
        np.asarray(h.result()["data"]["tokens"]), np.asarray(direct["tokens"])
    )
    assert 0.0 < store.resident_fraction("ds") < 1.0  # only group 0 resident
    assert store.resident_fraction("ds", [0, 1]) == 1.0


def test_multi_tenant_requests_share_one_residency(pool):
    """N concurrent tenants on one dataset = ONE prepare+upload."""
    store = pool.store
    store.evict()
    store.reset_cache_stats()
    srv = SageServer(pool)
    hs = [srv.read("ds", (0, 2)) for _ in range(6)]
    srv.run_until_idle()
    assert all(h.state is RequestState.FINISHED for h in hs)
    cs = store.cache_stats("ds")
    assert cs["misses"] == 1  # single preparation, everything else hits


def test_background_server_thread(pool):
    with SageServer(pool) as srv:
        done = []

        def client(i):
            h = srv.read("ds", (i % 3, i % 3 + 2))
            out = h.result(timeout=60)
            done.append((i, out is not None, h.state))

        ts = [threading.Thread(target=client, args=(i,)) for i in range(5)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=120)
    assert len(done) == 5
    assert all(ok and st is RequestState.FINISHED for _, ok, st in done)


# ---------------------------------------------------------------- generate
def test_generate_through_server_matches_engine(pool, tiny_engine):
    srv = SageServer(pool, engine=tiny_engine)
    prompt = np.arange(1, 9, dtype=np.int32)
    h1 = srv.generate(prompt=prompt)
    h2 = srv.generate(dataset="ds", block_range=(0, 1), max_prompt=12, kmer_k=3)
    srv.run_until_idle()
    assert srv.batcher.stats["generate_batches"] == 1  # one padded batch
    # greedy decoding is row-independent: the fused batch row must equal a
    # solo engine call on the same prompt
    solo = tiny_engine.generate([prompt])[0]
    np.testing.assert_array_equal(h1.result()["tokens"], solo)
    expect_p = prompts_from_store(
        pool.session(), "ds", vocab=tiny_engine.cfg.vocab, n_prompts=1,
        max_prompt=12, kmer_k=3, block_range=(0, 1),
    )[0]
    np.testing.assert_array_equal(
        h2.result()["tokens"], tiny_engine.generate([expect_p])[0]
    )


def test_generate_empty_prompt_range_aborts_cleanly(pool, tiny_engine):
    srv = SageServer(pool, engine=tiny_engine)
    # a range yielding no prompts: n_prompts filter on an empty block set is
    # impossible via the API, so force it with an absurd kmer_k
    h = srv.generate(dataset="ds", block_range=(0, 1), kmer_k=15, max_prompt=4)
    srv.run_until_idle()
    if h.state is RequestState.ABORTED:  # reads shorter than 15-mers only
        with pytest.raises(ValueError, match="no prompts"):
            list(h.chunks(timeout=0))
    else:  # dataset happened to have >=15-base reads: fine, it generated
        assert h.result() is not None


# ------------------------------------------------------------ engine fixes
def test_serve_config_not_shared_between_engines(tiny_engine):
    e1 = ServingEngine(tiny_engine.cfg, tiny_engine.params)
    e2 = ServingEngine(tiny_engine.cfg, tiny_engine.params)
    assert e1.sc is not e2.sc  # the shared-mutable-default bug
    e1.sc.temperature = 0.7
    assert e2.sc.temperature == 0.0


def test_temperature_guard_consistent_between_prefill_and_step(tiny_engine):
    """Both sampling sites share one floor: a denormal temperature behaves
    exactly like the 1e-6 floor instead of overflowing the decode loop."""
    prompts = [np.arange(1, 7, dtype=np.int32)]
    outs = {}
    for t in (1e-300, 1e-6):
        eng = ServingEngine(
            tiny_engine.cfg, tiny_engine.params,
            ServeConfig(max_prompt=16, max_new=6, temperature=t, seed=9),
        )
        outs[t] = eng.generate(prompts)[0]
        assert outs[t].min() >= 0 and outs[t].max() < tiny_engine.cfg.vocab
    np.testing.assert_array_equal(outs[1e-300], outs[1e-6])


def test_generate_empty_batch(tiny_engine):
    assert tiny_engine.generate([]) == []


# ----------------------------------------------- prompts_from_store edges
def test_prompts_n_prompts_exceeding_available(pool):
    sess = pool.session()
    out = sess.read("ds", (0, 1), fmt="kmer", kmer_k=4)
    lens = np.asarray(out["read_len"])[0]
    n_real = int(np.asarray(out["n_reads"])[0])
    eligible = int((lens[:n_real] // 4 > 0).sum())
    ps = prompts_from_store(
        sess, "ds", vocab=259, n_prompts=10_000, kmer_k=4, block_range=(0, 1)
    )
    assert len(ps) == eligible  # over-asking returns what exists, no pad
    assert all(p.size > 0 for p in ps)


def test_prompts_all_zero_kmer_blocks_return_empty():
    """A range where every read is shorter than one k-mer yields []."""
    ref = make_reference(8_000, seed=74)
    rng = np.random.default_rng(0)
    reads = [ref[p : p + 10].copy() for p in rng.integers(0, 7000, size=12)]
    quals = [np.full(10, 70, np.uint8) for _ in reads]
    rs = ReadSet(reads=reads, quals=quals, kind="short", profile="tiny")
    store = SageStore()
    store.write("short", rs, ref, token_target=2048)
    assert prompts_from_store(
        store.session(), "short", vocab=4**8, kmer_k=15, n_prompts=4
    ) == []


def test_prompts_max_prompt_truncation_prefix_parity(pool):
    """max_prompt truncation keeps the k-mer PREFIX — the same prefix the
    engine's left-pad slot layout keeps (``p[:P]``), so pre-truncating at
    the feed and truncating at the slot agree."""
    sess = pool.session()
    kw = dict(vocab=259, n_prompts=6, kmer_k=4, block_range=(0, 2))
    long = prompts_from_store(sess, "ds", max_prompt=32, **kw)
    short = prompts_from_store(sess, "ds", max_prompt=8, **kw)
    assert len(long) == len(short)
    for lo, sh in zip(long, short):
        assert sh.size == min(8, lo.size)
        np.testing.assert_array_equal(sh, lo[: sh.size])


def test_prompt_slot_truncation_matches_pretruncated(pool, tiny_engine):
    """Feeding a prompt longer than the engine slot equals feeding its
    pre-truncated prefix (the left-pad layout keeps token P-1 hot)."""
    P = tiny_engine.sc.max_prompt
    long_prompt = np.arange(1, P + 9, dtype=np.int32)  # P + 8 tokens
    a = tiny_engine.generate([long_prompt])[0]
    b = tiny_engine.generate([long_prompt[:P]])[0]
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------------- session pool glue
def test_session_pool_shares_sessions_and_store(pool):
    s1 = pool.session()
    s2 = pool.session()
    s3 = pool.session(use_pallas=True)
    assert s1 is s2 and s1 is not s3
    assert pool.n_sessions == 2
    assert s1.store is pool.store


def test_pipeline_reuses_pooled_session(pool):
    pipe = pool.pipeline("ds", vocab_size=259, batch=2, seq_len=16)
    assert pipe.session is pool.session()
    assert pipe.store is pool.store
    batch = next(pipe.batches())
    assert batch["tokens"].shape == (2, 16)


def test_pipeline_rejects_foreign_session(pool):
    other = SageStore()
    with pytest.raises(ValueError, match="different store"):
        SageTokenPipeline(
            "ds", 259, 2, 16, store=other, session=pool.session()
        )


def test_cache_stats_reset(pool):
    pool.session().read("ds", (0, 1))
    assert pool.store.cache_stats()["total"]["misses"] + \
        pool.store.cache_stats()["total"]["hits"] > 0
    pool.store.reset_cache_stats()
    assert pool.store.cache_stats() == {
        "per_dataset": {}, "total": {"hits": 0, "misses": 0, "evictions": 0}
    }
    assert pool.store.cache_stats("ds") == {"hits": 0, "misses": 0, "evictions": 0}


def test_residency_scoring_errors_counted_not_swallowed_silently(pool):
    """Admission scoring never raises, but scoring failures are no longer
    invisible: unresolvable ranges score 0.0 AND bump the counter, while
    an unregistered dataset is a defined 0.0 (no error involved)."""
    from repro.serving import Request

    before = pool.residency_score_errors
    assert pool.request_residency(Request(kind="read", dataset="nope")) == 0.0
    assert pool.residency_score_errors == before
    bad = Request(kind="read", dataset="ds", block_range=(0, 10_000))
    assert pool.request_residency(bad) == 0.0
    assert pool.residency_score_errors == before + 1
    assert pool.stats()["residency_score_errors"] == before + 1
    # a well-formed request still scores without touching the counter
    ok = Request(kind="read", dataset="ds", block_range=(0, 1))
    assert 0.0 <= pool.request_residency(ok) <= 1.0
    assert pool.residency_score_errors == before + 1
