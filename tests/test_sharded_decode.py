"""Multi-device SAGe: block-sharded residency + shard_map decode.

The acceptance contract of the sharded hot path: sharded decode is
bit-identical to the single-device reference for every format and both
decode paths, the per-shard bucket padding keeps the zero-retrace
guarantee, the mask contract holds per shard, and the k-mer token stream
is invariant to the shard count.

Multi-shard cases need >1 visible device — run with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI sharded
step does); on a single device only the degenerate shards=1 paths run.
"""

import numpy as np
import pytest

import jax
from jax.sharding import NamedSharding

from repro.core import SageStore, reset_trace_counts, trace_counts
from repro.core.decode_jax import decode_blocks_sharded, pad_block_ids
from repro.data.pipeline import SageTokenPipeline
from repro.distributed.sharding import block_shard_count, make_block_mesh

NDEV = len(jax.devices())
SHARDS = [s for s in (1, 2, 4) if s <= NDEV]


@pytest.fixture(scope="module")
def sharded_store():
    from repro.genomics.synth import make_reference, sample_read_set

    # seed 41 read set contains in-read N dropouts -> exercises the
    # N-block-vs-PAD k-mer disambiguation across shard counts
    ref = make_reference(30_000, seed=41)
    rs = sample_read_set(ref, "illumina", depth=3, seed=42)
    store = SageStore(max_prepared=2)
    sf = store.write("ds", rs, ref, token_target=3072)
    assert sf.meta.n_blocks >= 9
    return store, sf


# ------------------------------------------------------------- bucket math
def test_pad_block_ids_rounds_to_bucket_times_shards():
    ids, valid = pad_block_ids(np.arange(5), shards=4)
    assert ids.size == 8  # bucket(ceil(5/4)) * 4 = 2 * 4
    assert valid.tolist() == [1] * 5 + [0] * 3
    ids, valid = pad_block_ids(np.arange(5), shards=2)
    assert ids.size == 8  # bucket(3) * 2 = 4 * 2
    ids, valid = pad_block_ids(np.arange(5))  # shards=1: the old rule
    assert ids.size == 8 and valid.sum() == 5
    ids, valid = pad_block_ids(np.arange(8), shards=4)  # already even
    assert ids.size == 8 and valid.sum() == 8
    with pytest.raises(ValueError):
        pad_block_ids(np.arange(3), shards=0)


@pytest.mark.skipif(NDEV < 4, reason="needs >=4 devices (force host devices)")
def test_session_mesh_must_match_store_residency(sharded_store):
    """Resident arrays are committed to the store mesh; a different session
    mesh must be rejected eagerly, not die inside jit."""
    _, sf = sharded_store
    store = SageStore(shards=4)
    store.register("ds", sf)
    with pytest.raises(ValueError, match="residency mesh"):
        store.session(shards=2)
    store.session(shards=1)  # single-device decode over sharded residency: ok
    store.session(shards=4)  # matching override: ok
    with pytest.raises(ValueError, match="not both"):
        store.session(mesh=store.mesh, shards=4)


def test_bucketed_decode_rejects_conflicting_decoder_args(sharded_store):
    store, _ = sharded_store
    db = store.prepared("ds")
    mesh = make_block_mesh(1)
    with pytest.raises(ValueError, match="decoder_key"):
        from repro.core.decode_jax import decode_blocks_bucketed
        decode_blocks_bucketed(db, np.arange(2), mesh=mesh, decoder=lambda s: s)
    with pytest.raises(ValueError, match="sharded path"):
        from repro.core.decode_jax import decode_blocks_bucketed
        decode_blocks_bucketed(db, np.arange(2), decoder_key=("pallas", ()))


def test_make_block_mesh_rejects_oversubscription():
    with pytest.raises(ValueError, match="xla_force_host_platform_device_count"):
        make_block_mesh(NDEV + 1)
    mesh = make_block_mesh(1)
    assert block_shard_count(mesh) == 1 and mesh.axis_names == ("blocks",)
    assert block_shard_count(None) == 1


# ------------------------------------------------- residency + bit-identity
@pytest.mark.skipif(NDEV < 2, reason="needs >1 device (force host devices)")
def test_residency_is_block_sharded(sharded_store):
    _, sf = sharded_store
    store = SageStore(shards=2)
    store.register("ds", sf)
    db = store.prepared("ds")
    padded = db.n_blocks + (-db.n_blocks) % 2
    for name, arr in db.arrays.items():
        assert isinstance(arr.sharding, NamedSharding), name
        assert arr.sharding.spec[0] == "blocks", name
        assert arr.shape[0] == padded, name  # zero-padded to even shards
        # each device holds only its shard of the (padded) block axis
        shard_rows = {s.data.shape[0] for s in arr.addressable_shards}
        assert shard_rows == {padded // 2}, name
    assert db.mesh is not None


@pytest.mark.parametrize("shards", SHARDS)
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("fmt", ["2bit", "onehot", "kmer"])
def test_sharded_read_bit_identical(sharded_store, shards, use_pallas, fmt):
    single_store, sf = sharded_store
    ref_out = single_store.session().read("ds", fmt=fmt, kmer_k=4)
    store = SageStore(shards=shards)
    store.register("ds", sf)
    sess = store.session(use_pallas=use_pallas)
    out = sess.read("ds", fmt=fmt, kmer_k=4)
    from repro.core import get_format

    keys = ["tokens", "n_reads", "n_tokens", "read_start", "read_len",
            "read_pos", get_format(fmt).out_key]
    for key in keys:
        np.testing.assert_array_equal(
            np.asarray(out[key]), np.asarray(ref_out[key]), err_msg=key
        )
    # ranged + fancy-id reads match the whole-file slice
    part = sess.read("ds", [6, 0, 3], fmt=fmt, kmer_k=4)
    for key in keys:
        np.testing.assert_array_equal(
            np.asarray(part[key]), np.asarray(ref_out[key])[[6, 0, 3]], err_msg=key
        )


@pytest.mark.skipif(NDEV < 2, reason="needs >1 device (force host devices)")
def test_sharded_mask_contract_pad_occupant_invariance(sharded_store):
    _, sf = sharded_store
    store = SageStore(shards=2)
    store.register("ds", sf)
    db = store.prepared("ds")
    mesh = store.mesh
    ids_a = np.asarray([2, 4, 1, 0, 5, 3], dtype=np.int64)
    ids_b = np.asarray([2, 4, 1, 7, 8, 6], dtype=np.int64)
    valid = np.asarray([1, 1, 1, 0, 0, 0], dtype=np.int32)
    out_a = decode_blocks_sharded(db, ids_a, valid, mesh=mesh)
    out_b = decode_blocks_sharded(db, ids_b, valid, mesh=mesh)
    for key in out_a:
        np.testing.assert_array_equal(
            np.asarray(out_a[key]), np.asarray(out_b[key]), err_msg=key
        )
    assert (np.asarray(out_a["n_reads"])[3:] == 0).all()


@pytest.mark.parametrize("shards", SHARDS)
def test_sharded_reads_do_not_retrace_within_bucket(sharded_store, shards):
    _, sf = sharded_store
    store = SageStore(shards=shards)
    store.register("ds", sf)
    sess = store.session()
    per = 2 * shards  # per-shard bucket 2: lengths in (shards, 2*shards]
    sess.read("ds", (0, per))  # warm the bucket
    reset_trace_counts()
    sess.read("ds", (1, 1 + per))
    sess.read("ds", list(range(shards + 1)) if shards > 1 else [1, 0])
    counts = trace_counts()
    assert counts.get("decode_shard", 0) == 0, counts
    assert counts.get("decode_vmap", 0) == 0, counts


# ------------------------------------------- k-mer stream shard invariance
@pytest.mark.parametrize("use_pallas", [False, True])
def test_kmer_stream_invariant_across_shards_and_paths(sharded_store, use_pallas):
    """Same cursor -> same tokens, bit for bit, for shards in {1,2,4} x
    decode path (the pipeline's deterministic-stream contract)."""
    _, sf = sharded_store

    def stream(shards, n_fetches=6):
        p = SageTokenPipeline(sf, vocab_size=256, batch=2, seq_len=16,
                              shards=shards if shards > 1 else None,
                              use_pallas_decode=use_pallas, blocks_per_fetch=3)
        chunks = [np.asarray(p._fetch_tokens()) for _ in range(n_fetches)]
        return np.concatenate(chunks), p.cursor

    ref_stream, ref_cursor = stream(1)
    assert ref_stream.size > 0
    for shards in SHARDS[1:]:
        got, cursor = stream(shards)
        np.testing.assert_array_equal(got, ref_stream, err_msg=f"shards={shards}")
        assert cursor == ref_cursor
    # and vs the vmap single-shard reference when we are the pallas variant
    if use_pallas:
        vm = SageTokenPipeline(sf, vocab_size=256, batch=2, seq_len=16,
                               blocks_per_fetch=3)
        chunks = [np.asarray(vm._fetch_tokens()) for _ in range(6)]
        np.testing.assert_array_equal(np.concatenate(chunks), ref_stream)
