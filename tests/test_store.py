"""SageStore / SageReadSession: the session-based streaming read API.

Covers the acceptance contract: ranged reads match whole-file decode for
every registered FormatSpec, the SAGe_ISP stream delivers every block to a
consumer, the LRU keeps at most ``max_prepared`` datasets device-resident,
and the container round-trips both read kinds with absent streams omitted.
"""

import numpy as np
import pytest

import jax

from repro.core import (
    SageStore,
    available_formats,
    get_format,
)
from repro.core.encoder import SageEncoder
from repro.core.format import SageFile
from repro.genomics.filter_jax import filter_store_blocks
from repro.genomics.mapper import map_store_reads
from repro.genomics.synth import make_reference, sample_read_set
from repro.serving.engine import prompts_from_store


@pytest.fixture(scope="module")
def small_store():
    ref = make_reference(24_000, seed=40)
    rs = sample_read_set(ref, "illumina", depth=3, seed=41)
    store = SageStore(max_prepared=2)
    store.write("ds", rs, ref, token_target=4096)
    return store, ref, rs


# ------------------------------------------------------------- SAGe_Read
@pytest.mark.parametrize("fmt", sorted(["2bit", "onehot", "kmer"]))
def test_ranged_read_matches_whole_file_slice(small_store, fmt):
    """read(name, (lo, hi)) must equal the corresponding slice of a
    whole-file decode for every FormatSpec (blocks decode independently)."""
    store, _, _ = small_store
    sess = store.session()
    whole = sess.read("ds", fmt=fmt, kmer_k=4)
    nb = store.n_blocks("ds")
    lo, hi = 1, min(4, nb)
    part = sess.read("ds", (lo, hi), fmt=fmt, kmer_k=4)
    spec = get_format(fmt)
    for key in ("tokens", "read_start", "read_len", "read_pos", "n_reads", spec.out_key):
        np.testing.assert_array_equal(
            np.asarray(part[key]), np.asarray(whole[key])[lo:hi], err_msg=key
        )
    np.testing.assert_array_equal(part["block_ids"], np.arange(lo, hi))


def test_every_registered_format_is_tested():
    assert set(available_formats()) == {"2bit", "onehot", "kmer"}


def test_pallas_session_matches_vmap_session(small_store):
    store, _, _ = small_store
    vm = store.session().read("ds", (0, 2), fmt="kmer", kmer_k=4)
    pl = store.session(use_pallas=True).read("ds", (0, 2), fmt="kmer", kmer_k=4)
    for key in ("tokens", "read_start", "read_len", "n_reads", "kmer"):
        np.testing.assert_array_equal(np.asarray(pl[key]), np.asarray(vm[key]), err_msg=key)


def test_block_range_forms_and_validation(small_store):
    store, _, _ = small_store
    sess = store.session()
    nb = store.n_blocks("ds")
    one = sess.read("ds", 0)
    assert np.asarray(one["tokens"]).shape[0] == 1
    explicit = sess.read("ds", [2, 0])
    np.testing.assert_array_equal(explicit["block_ids"], [2, 0])
    with pytest.raises(ValueError):
        sess.read("ds", (0, nb + 1))
    with pytest.raises(ValueError):
        sess.read("ds", (3, 3))
    with pytest.raises(KeyError):
        sess.read("nope")


def test_kmer_format_requires_k_with_context(small_store):
    store, _, _ = small_store
    with pytest.raises(ValueError, match=r"SAGe_Read\('ds'\).*kmer_k"):
        store.session().read("ds", fmt="kmer")


# -------------------------------------------------------------- SAGe_ISP
def test_read_stream_consumer_covers_every_block(small_store):
    store, _, rs = small_store
    sess = store.session()
    seen: list[np.ndarray] = []

    def consumer(sb):
        seen.append(np.asarray(sb.block_ids))
        return int(np.asarray(sb.data["n_reads"]).sum())

    counts = sess.read_stream("ds", consumer, blocks_per_fetch=3)
    assert np.concatenate(seen).tolist() == list(range(store.n_blocks("ds")))
    assert sum(counts) == rs.n_reads


def test_read_stream_wrap_epochs_and_bounds(small_store):
    store, _, _ = small_store
    sess = store.session()
    nb = store.n_blocks("ds")
    batches = list(
        sess.read_stream("ds", fmt="2bit", blocks_per_fetch=nb - 1, wrap=True, max_fetches=3)
    )
    assert [b.epoch for b in batches] == [0, 0, 1]  # second fetch wraps
    np.testing.assert_array_equal(batches[1].block_ids[0], (nb - 1) % nb)
    with pytest.raises(ValueError):
        sess.read_stream("ds", lambda b: None, wrap=True)  # unbounded consumer
    with pytest.raises(ValueError):
        sess.read_stream("ds", start_block=nb)  # eager bounds check
    with pytest.raises(ValueError):
        sess.read_stream("ds", blocks_per_fetch=0)  # would spin forever


def test_read_stream_prefetched_matches_sync(small_store):
    store, _, _ = small_store
    sess = store.session()
    sync = list(sess.read_stream("ds", blocks_per_fetch=2, prefetch=0))
    pre = list(sess.read_stream("ds", blocks_per_fetch=2, prefetch=2))
    assert len(sync) == len(pre)
    for a, b in zip(sync, pre):
        np.testing.assert_array_equal(np.asarray(a.data["tokens"]), np.asarray(b.data["tokens"]))


# ----------------------------------------------------------- store management
def test_lru_keeps_at_most_max_prepared(small_store):
    _, ref, rs = small_store
    store = SageStore(max_prepared=2)
    for name in ("a", "b", "c"):
        store.register(name, SageEncoder(ref, token_target=4096).encode(rs))
    store.prepared("a")
    store.prepared("b")
    store.prepared("c")  # evicts "a"
    assert store.prepared_names == ("b", "c")
    store.prepared("b")  # refresh -> "c" is now oldest
    store.prepared("a")  # evicts "c"
    assert store.prepared_names == ("b", "a")
    store.evict()
    assert store.prepared_names == ()


def test_lazy_path_registration(small_store, tmp_path):
    store, _, rs = small_store
    p = tmp_path / "ds.sage.npz"
    store.file("ds").save(p)
    lazy = SageStore()
    lazy.register("fromdisk", str(p))
    out = lazy.session().read("fromdisk")
    ref_out = store.session().read("ds")
    np.testing.assert_array_equal(np.asarray(out["tokens"]), np.asarray(ref_out["tokens"]))


# ------------------------------------------------- container save/load kinds
def test_fixed_length_file_omits_length_streams(tmp_path):
    """Fixed-read-length containers omit leng/lena on disk (per format.py's
    stream table) and load() must tolerate their absence."""
    from repro.genomics.synth import ReadSet

    ref = make_reference(12_000, seed=60)
    reads = [ref[i * 150 : i * 150 + 150].copy() for i in range(40)]
    rs = ReadSet(reads=reads, quals=[np.full(150, 70, np.uint8)] * 40,
                 kind="short", profile="illumina")
    sf = SageEncoder(ref, token_target=2048).encode(rs)
    assert sf.meta.fixed_read_len == 150 and sf.streams["leng"].size == 0
    p = tmp_path / "fixed.sage.npz"
    sf.save(p)
    z = np.load(p)
    assert "s_leng" not in z.files and "s_lena" not in z.files
    sf2 = SageFile.load(p)
    assert sf2.streams["leng"].size == 0
    store = SageStore()
    store.register("orig", sf)
    store.register("reload", sf2)
    sess = store.session()
    np.testing.assert_array_equal(
        np.asarray(sess.read("reload")["tokens"]), np.asarray(sess.read("orig")["tokens"])
    )


def test_variable_length_file_roundtrips_length_streams(tmp_path):
    ref = make_reference(30_000, seed=50)
    rs = sample_read_set(ref, "ont", depth=1.5, seed=51, max_reads=10)
    sf = SageEncoder(ref, token_target=8192).encode(rs)
    assert sf.meta.fixed_read_len == 0 and sf.streams["leng"].size > 0
    p = tmp_path / "var.sage.npz"
    sf.save(p)
    sf2 = SageFile.load(p)
    np.testing.assert_array_equal(sf2.streams["leng"], sf.streams["leng"])
    store = SageStore()
    store.register("var", sf)
    store.register("var2", sf2)
    sess = store.session()
    np.testing.assert_array_equal(
        np.asarray(sess.read("var")["tokens"]), np.asarray(sess.read("var2")["tokens"])
    )


# --------------------------------------------------------- consumer drivers
def test_prompts_from_store(small_store):
    store, _, _ = small_store
    prompts = prompts_from_store(
        store.session(), "ds", vocab=259, n_prompts=6, max_prompt=32, block_range=(0, 2)
    )
    assert len(prompts) == 6
    for p in prompts:
        assert p.dtype == np.int32 and 0 < p.size <= 32
        assert p.min() >= 0 and p.max() < 259


def test_map_store_reads_driver(small_store):
    store, ref, rs = small_store
    rep = map_store_reads(store.session(), "ds", ref, block_range=(0, 2), blocks_per_fetch=1)
    assert rep.total == int(np.asarray(store.session().read("ds", (0, 2))["n_reads"]).sum())
    assert rep.pruned + rep.mapped > 0.9 * rep.total


def test_filter_store_blocks_driver(small_store):
    store, ref, _ = small_store
    masks, pruned, total = filter_store_blocks(store.session(), "ds", (0, 3))
    assert masks.shape[0] == 3 and total > 0
    # every pruned read must REALLY be an exact forward match vs consensus
    out = jax.tree.map(np.asarray, store.session().read("ds", (0, 3)))
    for i in range(3):
        for r in np.nonzero(masks[i])[0]:
            s, l = int(out["read_start"][i][r]), int(out["read_len"][i][r])
            p = int(out["read_pos"][i][r])
            np.testing.assert_array_equal(out["tokens"][i][s : s + l], ref[p : p + l])
    assert pruned > 0


def test_health_unregistered_dataset_raises(small_store):
    """A typo'd monitoring probe must not read as a clean bill of health:
    health() on an unknown name raises a ValueError naming it."""
    store, _, _ = small_store
    with pytest.raises(ValueError, match="'nope' is not registered"):
        store.health("nope")
    assert store.health("ds")["ok"]  # the registered name still answers
    assert set(store.health()) == set(store.names())
