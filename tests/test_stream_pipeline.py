"""Pipelined streaming decode: the disk->host->device->Pallas scan pipeline.

Acceptance contract (ISSUE 10): ``mode="pipelined"`` streams are
bit-identical to the synchronous and async-dispatch paths across all
formats x both decode paths (including wrap and group-boundary spans on a
lazy v2 store); the dispatch window holds exactly N decodes in flight
(the historical N+1 is a regression); abandoning or erroring a stream
leaks no threads or file handles; background-I/O failures surface as the
same typed SageIOErrors at the exact fetch position they belong to; and
steady-state streaming re-traces nothing.
"""

import gc
import os
import threading
import time

import numpy as np
import pytest

from repro.core import SageStore
from repro.core.decode_jax import TRACE_COUNTS
from repro.core.encoder import SageEncoder
from repro.core.errors import IntegrityError
from repro.core.layout import write_v2
from repro.core.store import SageReadSession
from repro.core.streaming import PipelinedStream
from repro.genomics.synth import make_reference, sample_read_set
from repro.testing.faults import corrupt_group

GROUP_BLOCKS = 2


@pytest.fixture(scope="module")
def v2_ds(tmp_path_factory):
    """Encoded dataset + checksummed codec v2 container on disk."""
    ref = make_reference(30_000, seed=70)
    rs = sample_read_set(ref, "illumina", depth=3, seed=71)
    sf = SageEncoder(ref, token_target=2048).encode(rs)
    path = tmp_path_factory.mktemp("stream") / "ds.sage2"
    write_v2(sf, path, align=512)
    assert sf.meta.n_blocks >= 4 * GROUP_BLOCKS, "need several residency groups"
    return sf, str(path)


def fresh_store(path, **kw):
    kw.setdefault("group_blocks", GROUP_BLOCKS)
    store = SageStore(**kw)
    store.register("ds", path)
    return store


def batch_key_arrays(sb, fmt):
    keys = ["tokens", "n_reads", "n_tokens", "read_start", "read_len", "read_pos"]
    if fmt in ("onehot", "kmer"):
        keys.append(fmt)
    return {k: np.asarray(sb.data[k]) for k in keys} | {
        "block_ids": np.asarray(sb.block_ids),
        "epoch": sb.epoch, "next_block": sb.next_block, "next_epoch": sb.next_epoch,
    }


def assert_batches_equal(a, b, fmt):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        dx, dy = batch_key_arrays(x, fmt), batch_key_arrays(y, fmt)
        for k in dx:
            np.testing.assert_array_equal(dx[k], dy[k], err_msg=k)


# -------------------------------------------------------------- mode parity
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("fmt", ["2bit", "onehot", "kmer"])
def test_mode_parity_with_wrap_and_boundary_spans(v2_ds, fmt, use_pallas):
    """sync / dispatch / pipelined deliver bit-identical StreamBatch
    sequences on a lazy v2 store, including a wrap-around and fetches that
    straddle block-group boundaries (blocks_per_fetch=3, group_blocks=2)."""
    sf, path = v2_ds
    # start near the end so fetch 2 of 5 actually wraps; blocks_per_fetch=3
    # against group_blocks=2 keeps every fetch straddling a group boundary
    kw = dict(fmt=fmt, kmer_k=4, start_block=sf.meta.n_blocks - 4,
              blocks_per_fetch=3, wrap=True, max_fetches=5)
    out = {}
    for mode in ("sync", "dispatch", "pipelined"):
        store = fresh_store(path)
        sess = store.session(use_pallas=use_pallas)
        out[mode] = list(sess.read_stream("ds", mode=mode, **kw))
    assert_batches_equal(out["sync"], out["dispatch"], fmt)
    assert_batches_equal(out["sync"], out["pipelined"], fmt)


def test_pipelined_matches_sync_on_eager_store(v2_ds):
    """Eager (non-lazy) datasets stream through the same pipeline — the I/O
    stage simply has no disk groups to stage."""
    sf, _ = v2_ds
    store = SageStore()
    store.register("ds", sf)
    sess = store.session()
    kw = dict(fmt="2bit", blocks_per_fetch=2, max_fetches=3)
    a = list(sess.read_stream("ds", mode="sync", **kw))
    b = list(sess.read_stream("ds", mode="pipelined", **kw))
    assert_batches_equal(a, b, "2bit")
    assert store.io_stats["stream_fetches"] == 3


# ---------------------------------------------------------- dispatch window
def test_dispatch_window_holds_exactly_n_in_flight(v2_ds, monkeypatch):
    """dispatch=N dispatches exactly N groups before the first yield and at
    most N ahead of the consumer thereafter (the off-by-one that kept N+1
    in flight is a regression)."""
    sf, _ = v2_ds
    store = SageStore()
    store.register("ds", sf)
    sess = store.session()
    reads = []
    orig = SageReadSession.read

    def counting_read(self, *a, **kw):
        reads.append(1)
        return orig(self, *a, **kw)

    monkeypatch.setattr(SageReadSession, "read", counting_read)
    dispatch = 2
    it = sess.read_stream("ds", blocks_per_fetch=1, max_fetches=5,
                          dispatch=dispatch, mode="dispatch")
    seen = 0
    for _ in it:
        seen += 1
        if seen <= 3:  # window still refilling from the descriptor stream
            assert len(reads) == min(5, seen - 1 + dispatch)
    assert seen == 5 and len(reads) == 5


# ------------------------------------------------------------ overlap stats
def test_stream_stats_accounting_and_fold(v2_ds):
    _, path = v2_ds
    store = fresh_store(path)
    sess = store.session()
    stream = sess.read_stream("ds", mode="pipelined", blocks_per_fetch=2,
                              max_fetches=4, dispatch=2)
    n = sum(1 for _ in stream)
    assert n == 4
    s = stream.stats.to_dict()
    assert s["fetches"] == 4 and s["io_groups"] >= 4
    assert s["wall_seconds"] > 0
    assert s["inflight_hwm"] >= 2  # the window demonstrably ran ahead
    # double-buffered residency: covering groups of the in-flight fetches
    # only (dispatch slots + one boundary-shared group at most)
    assert s["slot_hwm"] <= max(2, 2) + 1
    assert -1.0 <= s["overlap_fraction"] < 1.0
    io = store.io_stats
    assert io["stream_fetches"] == 4
    assert io["stream_wall_seconds"] == pytest.approx(s["wall_seconds"])
    assert io["stream_overlap_fraction"] == pytest.approx(s["overlap_fraction"])


def test_wrap_stream_releases_retired_slots(v2_ds):
    """A long wrapped stream keeps device residency bounded: retired fetch
    slots release their groups (host cache keeps the bytes), so the
    store's prepared set never grows with stream length."""
    _, path = v2_ds
    store = fresh_store(path)
    sess = store.session()
    stream = sess.read_stream("ds", mode="pipelined", blocks_per_fetch=2,
                              wrap=True, max_fetches=12, dispatch=2)
    for _ in stream:
        with store._lock:
            # 2 slots x at most 2 covering groups each, + the fetch mid-upload
            assert len(store._prepared) <= 2 * 2 + 2
    assert stream.stats.slot_releases > 0


def test_steady_state_zero_retraces(v2_ds):
    _, path = v2_ds
    sess = fresh_store(path).session()
    list(sess.read_stream("ds", mode="pipelined", blocks_per_fetch=2,
                          max_fetches=3))  # warm every bucket this shape uses
    before = dict(TRACE_COUNTS)
    sess2 = fresh_store(path).session()
    out = list(sess2.read_stream("ds", mode="pipelined", blocks_per_fetch=2,
                                 max_fetches=3))
    assert len(out) == 3
    assert dict(TRACE_COUNTS) == before


# ---------------------------------------------------------------- teardown
def _wait_threads_settle(baseline, timeout=5.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        extra = set(threading.enumerate()) - baseline
        if not extra:
            return []
        time.sleep(0.05)
    return [t.name for t in set(threading.enumerate()) - baseline]


def test_abandoned_stream_leaks_no_threads_or_fds(v2_ds):
    _, path = v2_ds
    baseline_threads = set(threading.enumerate())
    fds_before = len(os.listdir("/proc/self/fd"))
    store = fresh_store(path)
    sess = store.session()
    stream = sess.read_stream("ds", mode="pipelined", blocks_per_fetch=2,
                              wrap=True, max_fetches=50)
    next(iter(stream))  # mid-stream abandon, worker queue full behind us
    del stream
    gc.collect()
    assert _wait_threads_settle(baseline_threads) == []
    del store, sess
    gc.collect()
    assert len(os.listdir("/proc/self/fd")) <= fds_before


def test_explicit_close_is_idempotent_and_joins_worker(v2_ds):
    _, path = v2_ds
    baseline_threads = set(threading.enumerate())
    sess = fresh_store(path).session()
    with sess.read_stream("ds", mode="pipelined", blocks_per_fetch=2,
                          wrap=True, max_fetches=50) as stream:
        next(stream)
    stream.close()  # second close: no-op
    assert _wait_threads_settle(baseline_threads) == []


def test_pipelined_validation_errors():
    store = SageStore()
    ref = make_reference(6_000, seed=72)
    rs = sample_read_set(ref, "illumina", depth=2, seed=73)
    store.write("ds", rs, ref, token_target=2048)
    sess = store.session()
    with pytest.raises(ValueError, match="mode must be one of"):
        sess.read_stream("ds", mode="turbo")
    with pytest.raises(ValueError, match="readahead must be >= 0"):
        sess.read_stream("ds", mode="pipelined", readahead=-1)
    with pytest.raises(ValueError, match="dispatch depth must be >= 1"):
        PipelinedStream(sess, "ds", dispatch=0)


# ------------------------------------------------------------ fault surface
def test_background_io_error_surfaces_typed_and_in_order(v2_ds, tmp_path):
    """Corruption hit by the background I/O stage raises the same typed
    IntegrityError a synchronous read would — at the failing fetch's
    position, after every earlier batch was delivered — and quarantines
    the group. No worker threads survive the failure."""
    _, path = v2_ds
    p = tmp_path / "ds.sage2"
    import shutil

    shutil.copy(path, p)
    corrupt_group(str(p), 1, GROUP_BLOCKS, byte=9, bit=6)
    baseline_threads = set(threading.enumerate())
    store = fresh_store(str(p))
    sess = store.session()
    stream = sess.read_stream("ds", mode="pipelined", blocks_per_fetch=GROUP_BLOCKS,
                              max_fetches=4, dispatch=1)
    first = next(stream)  # group 0 is clean and must be delivered first
    np.testing.assert_array_equal(first.block_ids, np.arange(GROUP_BLOCKS))
    with pytest.raises(IntegrityError) as ei:
        next(stream)
    assert ei.value.dataset == "ds" and ei.value.block_group == 1
    assert store.health("ds")["quarantined_groups"] == (1,)
    assert _wait_threads_settle(baseline_threads) == []
    # fail-fast thereafter: the quarantined group is refused without disk I/O
    with pytest.raises(IntegrityError, match="quarantined"):
        store.session().read("ds", (GROUP_BLOCKS, GROUP_BLOCKS + 1))
