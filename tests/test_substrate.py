"""Data pipeline, checkpoint, trainer fault tolerance, serving engine."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import ARCHS
from repro.core.api import kmer_special_ids, pick_k
from repro.core.encoder import SageEncoder
from repro.data.pipeline import SageTokenPipeline
from repro.genomics.synth import make_reference, sample_read_set
from repro.serving.engine import ServeConfig, ServingEngine
from repro.training.optimizer import AdamWConfig
from repro.training.steps import TrainOptions, init_train_state
from repro.training.trainer import StragglerMonitor, Trainer, TrainerConfig
from repro.models import lm


@pytest.fixture(scope="module")
def sagefile():
    ref = make_reference(30_000, seed=4)
    rs = sample_read_set(ref, "illumina", depth=3, seed=5)
    return SageEncoder(ref, token_target=4096).encode(rs)


def test_pipeline_deterministic_and_resumable(sagefile):
    p1 = SageTokenPipeline(sagefile, vocab_size=256, batch=2, seq_len=64)
    it = p1.batches()
    first = [next(it) for _ in range(4)]
    state = p1.state()
    fifth = next(it)
    # resume: new pipeline restored from the cursor reproduces batch #5
    p2 = SageTokenPipeline(sagefile, vocab_size=256, batch=2, seq_len=64)
    p2.restore(state)
    fifth2 = next(p2.batches())
    np.testing.assert_array_equal(fifth["tokens"], fifth2["tokens"])
    # tokens are in-vocab and not pad
    k = pick_k(256)
    sp = kmer_special_ids(k)
    for b in first:
        assert b["tokens"].max() < 256
        assert (b["tokens"] != sp["pad"]).all()


def _flat_kmer_stream(sf, vocab: int, n_tokens: int) -> np.ndarray:
    """Ground-truth flat k-mer stream (blocks cyclic, PAD dropped) — the
    pipeline's deterministic contract, independent of blocks_per_fetch."""
    p = SageTokenPipeline(sf, vocab_size=vocab, batch=1, seq_len=8)
    chunks: list[np.ndarray] = []
    while sum(c.size for c in chunks) < n_tokens:
        chunks.append(p._fetch_tokens())
    return np.concatenate(chunks)


def test_pipeline_restore_at_exact_block_boundary(sagefile):
    p = SageTokenPipeline(sagefile, vocab_size=256, batch=2, seq_len=16)
    boundary = int(p._kpb[:3].sum())  # consumed count ending exactly at block 3
    p.restore({"cursor": {"epoch": 0, "block": 0, "consumed": boundary}})
    assert p.cursor.block == 3 and p._skip == 0  # boundary maps to next block, no skip
    need = 2 * 17
    got = next(p.batches())
    exp = _flat_kmer_stream(sagefile, 256, boundary + need)[boundary : boundary + need]
    np.testing.assert_array_equal(got["tokens"], exp.reshape(2, 17)[:, :-1])


def test_pipeline_restore_after_full_epoch(sagefile):
    p = SageTokenPipeline(sagefile, vocab_size=256, batch=2, seq_len=16)
    total = int(p._kpb.sum())
    consumed = 2 * total + int(p._kpb[0] // 2)  # two full epochs + mid-block
    p.restore({"cursor": {"epoch": 0, "block": 0, "consumed": consumed}})
    assert p.cursor.epoch == 2
    need = 2 * 17
    flat = _flat_kmer_stream(sagefile, 256, total)[:total]
    cyc = np.concatenate([flat, flat])  # the stream is cyclic across epochs
    rem = consumed % total
    got = next(p.batches())
    np.testing.assert_array_equal(got["tokens"], cyc[rem : rem + need].reshape(2, 17)[:, :-1])


def test_pipeline_blocks_per_fetch_exceeding_n_blocks(sagefile):
    nb = sagefile.meta.n_blocks
    big = SageTokenPipeline(sagefile, vocab_size=256, batch=2, seq_len=16,
                            blocks_per_fetch=nb + 3)
    small = SageTokenPipeline(sagefile, vocab_size=256, batch=2, seq_len=16,
                              blocks_per_fetch=2)
    bit, sit = big.batches(), small.batches()
    for _ in range(3):
        np.testing.assert_array_equal(next(bit)["tokens"], next(sit)["tokens"])
    # restore still replays the exact stream when one fetch spans >1 epoch
    state = big.state()
    nxt = next(bit)
    big2 = SageTokenPipeline(sagefile, vocab_size=256, batch=2, seq_len=16,
                             blocks_per_fetch=nb + 3)
    big2.restore(state)
    np.testing.assert_array_equal(next(big2.batches())["tokens"], nxt["tokens"])


def test_pipeline_refuses_to_clobber_shared_store_dataset(sagefile):
    from repro.core import SageEncoder, SageStore
    from repro.genomics.synth import make_reference, sample_read_set

    store = SageStore()
    store.register("train", sagefile)
    other_ref = make_reference(10_000, seed=9)
    other = SageEncoder(other_ref, token_target=2048).encode(
        sample_read_set(other_ref, "illumina", depth=1, seed=10)
    )
    with pytest.raises(ValueError, match="already registered"):
        SageTokenPipeline(other, vocab_size=256, batch=2, seq_len=16, store=store)
    # same SageFile or a unique name are both fine
    SageTokenPipeline(sagefile, vocab_size=256, batch=2, seq_len=16, store=store)
    SageTokenPipeline(other, vocab_size=256, batch=2, seq_len=16, store=store, name="other")
    assert set(store.names()) == {"train", "other"}


def test_pipeline_prefetch_matches_sync(sagefile):
    p1 = SageTokenPipeline(sagefile, vocab_size=256, batch=2, seq_len=32)
    p2 = SageTokenPipeline(sagefile, vocab_size=256, batch=2, seq_len=32)
    sync = [next(p1.batches()) for _ in range(3)]
    pre = p2.prefetched()
    asyncb = [next(pre) for _ in range(3)]
    for a, b in zip(sync, asyncb):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cm = CheckpointManager(tmp_path, keep_last=2)
    state = {"w": jnp.arange(12.0).reshape(3, 4), "n": {"b": jnp.ones((2,), jnp.int32)}}
    for s in (1, 2, 3):
        cm.save(s, state, extra={"tag": s}, block=True)
    assert cm.steps() == [2, 3]  # GC keeps last 2
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored, extra, step = cm.restore(like, verify=True)
    assert step == 3 and extra["tag"] == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))


def test_checkpoint_detects_corruption(tmp_path):
    cm = CheckpointManager(tmp_path)
    state = {"w": jnp.ones((4, 4))}
    cm.save(1, state, block=True)
    f = next((tmp_path / "step_1").glob("w.npy"))
    arr = np.load(f)
    arr[0, 0] = 42
    np.save(f, arr)
    with pytest.raises(IOError):
        cm.restore({"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}, verify=True)


def test_trainer_resume_after_interrupt(sagefile, tmp_path):
    cfg = ARCHS["qwen2-1.5b"].reduced()
    opts = TrainOptions(chunk=32, adamw=AdamWConfig(lr=1e-3, total_steps=20))
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg, opts)
    pipe = SageTokenPipeline(sagefile, cfg.vocab, batch=2, seq_len=32)
    tc = TrainerConfig(total_steps=6, ckpt_every=3, log_every=100, ckpt_dir=str(tmp_path))
    t1 = Trainer(tc, cfg, opts, params, opt, iter(pipe.batches()))
    t1.run(pipeline=pipe)
    assert t1.step == 6
    # simulate a fresh process: new trainer resumes from step 6 and continues
    params2, opt2 = init_train_state(jax.random.PRNGKey(0), cfg, opts)
    pipe2 = SageTokenPipeline(sagefile, cfg.vocab, batch=2, seq_len=32)
    tc2 = TrainerConfig(total_steps=9, ckpt_every=3, log_every=100, ckpt_dir=str(tmp_path))
    t2 = Trainer(tc2, cfg, opts, params2, opt2, iter(pipe2.batches()))
    assert t2.maybe_resume(pipe2)
    assert t2.step == 6
    t2.run(pipeline=pipe2)
    assert t2.step == 9


def test_nan_circuit_breaker():
    cfg = ARCHS["qwen2-1.5b"].reduced()
    opts = TrainOptions(chunk=32)
    from repro.training.steps import make_train_step

    params, opt = init_train_state(jax.random.PRNGKey(1), cfg, opts)
    step = jax.jit(make_train_step(cfg, opts))
    bad = {"tokens": jnp.zeros((2, 32), jnp.int32),
           "labels": jnp.zeros((2, 32), jnp.int32),
           "loss_mask": jnp.full((2, 32), jnp.nan)}
    p0 = jax.tree.map(lambda x: np.asarray(x).copy(), params)
    new_p, new_o, m = step(params, opt, bad)
    assert not np.isfinite(float(m["loss"]))
    for a, b in zip(jax.tree.leaves(new_p), jax.tree.leaves(p0)):
        np.testing.assert_array_equal(np.asarray(a), b)  # update skipped


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(warmup=3)
    seen = []
    mon.hook = lambda step, dt, ew: seen.append(step)
    for i in range(10):
        mon.observe(i, 0.1)
    assert mon.observe(99, 1.0)  # 10x slower
    assert mon.anomalies == 1 and seen == [99]


def test_serving_engine_greedy_decode():
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params = lm.init_params(jax.random.PRNGKey(2), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(max_prompt=16, max_new=8))
    prompts = [np.arange(5, dtype=np.int32), np.arange(9, dtype=np.int32)]
    outs = eng.generate(prompts)
    assert len(outs) == 2 and all(o.shape == (8,) for o in outs)
    assert all(0 <= o.min() and o.max() < cfg.vocab for o in outs)
    # greedy decode is deterministic
    outs2 = eng.generate(prompts)
    np.testing.assert_array_equal(outs[0], outs2[0])
