"""Tests for dataset-adaptive bit-width class tuning."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.tuning import assign_classes, bitlen, tune_classes


@given(st.lists(st.integers(0, 2**40), min_size=1, max_size=200))
@settings(max_examples=60, deadline=None)
def test_bitlen_matches_python(vals):
    v = np.asarray(vals, dtype=np.uint64)
    got = bitlen(v)
    exp = np.asarray([x.bit_length() for x in vals])
    assert np.array_equal(got, exp)


@given(st.lists(st.integers(0, 2**20), min_size=1, max_size=300))
@settings(max_examples=40, deadline=None)
def test_classes_cover_all_values(vals):
    v = np.asarray(vals, dtype=np.uint64)
    widths = tune_classes(v)
    cls = assign_classes(v, widths)
    w = np.asarray(widths)[cls]
    assert np.all(w >= bitlen(v)), "assigned width must fit the value"


def test_skewed_distribution_prefers_small_widths():
    # paper Fig 6a: heavily skewed -> small widths get the cheap guide codes
    rng = np.random.default_rng(0)
    small = rng.integers(0, 2, 10_000)  # 1-bit values
    big = rng.integers(1 << 10, 1 << 12, 100)  # 12-bit values
    v = np.concatenate([small, big]).astype(np.uint64)
    widths = tune_classes(v)
    assert widths[0] <= 2, f"most frequent class should be narrow, got {widths}"
    # and total cost must beat fixed-width encoding
    cls = assign_classes(v, widths)
    cost = int(np.sum(cls + 1 + np.asarray(widths)[cls]))
    fixed = v.size * 12
    assert cost < fixed


def test_single_value_degenerate():
    widths = tune_classes(np.zeros(10, dtype=np.uint64))
    cls = assign_classes(np.zeros(10, dtype=np.uint64), widths)
    assert np.all(np.asarray(widths)[cls] >= 0)
