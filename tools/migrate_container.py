"""Migrate SAGe containers between on-disk layouts — and heal them.

v1 (monolithic ``.npz``, whole-file decompress on every open) -> v2
(block-extent container: header + one alignment-padded extent per block,
lazy ranged reads — see DESIGN.md §7), and back for compatibility.

  PYTHONPATH=src python tools/migrate_container.py reads.sage.npz reads.sage2
  PYTHONPATH=src python tools/migrate_container.py reads.sage2 back.sage.npz --to-v1
  PYTHONPATH=src python tools/migrate_container.py in out --verify  # bit-identity

Self-healing (DESIGN.md §10):

  # re-write with a parity section (xor = 1 shard/group, rs = m shards)
  tools/migrate_container.py reads.sage2 prot.sage2 --add-parity xor
  tools/migrate_container.py reads.sage2 prot.sage2 --add-parity rs \\
      --parity-group 16 --parity-shards 2
  # scan + reconstruct + rewrite damaged extents of a parity container
  # IN PLACE (atomic tmp + fsync + rename); exits non-zero when damage
  # exceeds the parity budget
  tools/migrate_container.py damaged.sage2 --repair

``--verify`` re-opens the migrated container, materializes it, and diffs
every section (meta, directory, consensus, all 14 streams) against the
source — exits non-zero on any mismatch. On v2 output this drives the full
checksum layer (header CRCs, per-extent CRC32C, commit footer), so a
corrupted or torn output also fails verify, printing the failing section.

``--legacy`` writes the pre-checksum v2 layout (no CRC section, no commit
footer) — for readers that predate the integrity format.

Compression (DESIGN.md §11): v2 output is codec-compressed by default —
per-extent word truncation + nibble dictionaries, compact binary
directory/extent tables, payload-sized slots. ``--recompress`` makes that
intent explicit for v2 -> v2 migrations (re-encoding an old raw container
shrinks it by orders of magnitude; old containers keep reading
bit-identically without migration). ``--no-codec`` (implied by
``--legacy``) writes the raw stride-aligned layout instead.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.errors import SageIOError  # noqa: E402
from repro.core.format import SageFile  # noqa: E402
from repro.core.layout import (  # noqa: E402
    DEFAULT_ALIGN,
    SageContainerV2,
    container_version,
    write_v2,
)


def _load_any(path: str) -> SageFile:
    if container_version(path) == 2:
        return SageContainerV2.open(path).to_sage_file()
    return SageFile.load(path)


def repair_in_place(path: str) -> int:
    """Scan every extent + parity shard of ``path``, reconstruct what
    parity can fix, and atomically rewrite it. Returns a process exit
    code; unrecoverable damage prints the typed error and fails."""
    c = SageContainerV2.open(path)
    bad = c.verify_blocks()
    if bad:
        try:
            rebuilt = c.reconstruct_blocks(bad)
        except SageIOError as e:
            print(f"REPAIR FAILED: {type(e).__name__}: {e}", file=sys.stderr)
            return 1
        c.rewrite_extents(rebuilt)
        print(f"repaired {len(rebuilt)} damaged extent(s): {sorted(rebuilt)}")
    # parity second: its recompute reads the (now clean) data extents
    bad_parity = c.verify_parity()
    if bad_parity:
        try:
            fixed = c.rebuild_parity(bad_parity)
        except SageIOError as e:
            print(f"REPAIR FAILED: {type(e).__name__}: {e}", file=sys.stderr)
            return 1
        c.rewrite_extents({}, fixed)
        print(f"rebuilt {len(fixed)} damaged parity shard(s): {sorted(fixed)}")
    if not bad and not bad_parity:
        print(f"{path}: clean — nothing to repair")
        return 0
    # fresh handle: prove the medium verifies end-to-end before reporting ok
    fresh = SageContainerV2.open(path)
    still = fresh.verify_blocks() + fresh.verify_parity()
    if still:
        print(f"REPAIR FAILED: re-verify still finds damage: {still}",
              file=sys.stderr)
        return 1
    print(f"{path}: repaired and re-verified clean")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("src", help="source container (v1 .npz or v2)")
    ap.add_argument("dst", nargs="?", default=None,
                    help="destination path (omitted for --repair, which is in place)")
    ap.add_argument("--to-v1", action="store_true",
                    help="write a v1 .npz instead of a v2 block-extent container")
    ap.add_argument("--align", type=int, default=None,
                    help="v2 extent alignment in bytes (default: the codec's "
                         f"small alignment, or {DEFAULT_ALIGN} with --no-codec)")
    ap.add_argument("--recompress", action="store_true",
                    help="re-encode every extent with the per-extent codec "
                         "(explicit form of the v2 default; rejects --no-codec)")
    ap.add_argument("--no-codec", action="store_true",
                    help="write the raw stride-aligned v2 layout instead of "
                         "codec-compressed extents")
    ap.add_argument("--verify", action="store_true",
                    help="re-open the output and check section-by-section bit-identity "
                         "(on v2 output this also runs the checksum layer)")
    ap.add_argument("--legacy", action="store_true",
                    help="write the pre-checksum v2 layout (no CRCs, no commit footer)")
    ap.add_argument("--add-parity", nargs="?", const="xor", default=None,
                    choices=("xor", "rs"), metavar="SCHEME",
                    help="write a self-healing v2 container: parity over every "
                         "--parity-group extents (default scheme: xor)")
    ap.add_argument("--parity-group", type=int, default=16,
                    help="extents per parity group (default 16)")
    ap.add_argument("--parity-shards", type=int, default=2,
                    help="parity shards per group for --add-parity rs (default 2)")
    ap.add_argument("--repair", action="store_true",
                    help="scan SRC for damage, reconstruct from parity, and "
                         "atomically rewrite it in place (no dst)")
    args = ap.parse_args(argv)

    if args.repair:
        if args.dst is not None or args.to_v1 or args.add_parity:
            ap.error("--repair is in place: give only the container path")
        return repair_in_place(args.src)
    if args.dst is None:
        ap.error("dst is required (unless --repair)")
    if args.add_parity and (args.to_v1 or args.legacy):
        ap.error("--add-parity needs the checksummed v2 layout "
                 "(drop --to-v1/--legacy)")
    if args.recompress and (args.no_codec or args.to_v1 or args.legacy):
        ap.error("--recompress writes codec-compressed v2 extents "
                 "(drop --no-codec/--to-v1/--legacy)")
    codec = not (args.no_codec or args.legacy)

    sf = _load_any(args.src)
    if args.to_v1:
        sf.save(args.dst)
        print(f"v1 <- {args.src}: {sf.meta.n_blocks} blocks, "
              f"{os.path.getsize(args.dst)/1e6:.2f} MB -> {args.dst}")
    else:
        stats = write_v2(sf, args.dst, align=args.align,
                         integrity=not args.legacy,
                         parity=args.add_parity,
                         parity_group=args.parity_group,
                         parity_shards=args.parity_shards,
                         codec=codec)
        parity_note = (
            f", parity {stats['parity']} x{stats['parity_shards']}/"
            f"{stats['parity_group']} (+{100 * stats['parity_overhead']:.1f}%)"
            if stats["parity"] else ""
        )
        if stats["codec"]:
            raw = stats["n_blocks"] * stats["payload_nbytes"]
            stored = stats["stored_payload_nbytes"]
            extent_note = (
                f"codec extents ({stored/1e6:.2f} MB stored / "
                f"{raw/1e6:.2f} MB decoded = {raw/max(stored, 1):.1f}x"
                + (f", {stats['dedup_blocks']} deduped"
                   if stats["dedup_blocks"] else "")
                + ")"
            )
        else:
            extent_note = (
                f"{stats['stride_nbytes']} B raw extents "
                f"(payload {stats['payload_nbytes']} B)"
            )
        print(f"v2 <- {args.src}: {stats['n_blocks']} blocks x "
              f"{extent_note}, "
              f"header {stats['header_nbytes']/1e3:.1f} KB"
              f"{' (legacy, unchecksummed)' if args.legacy else ''}{parity_note}, "
              f"total {stats['file_nbytes']/1e6:.2f} MB -> {args.dst}")

    if args.verify:
        try:
            probs = _load_any(args.dst).diff(sf)
        except SageIOError as e:
            section = e.section or "unknown section"
            print(f"VERIFY FAILED: {type(e).__name__} in {section}: {e}",
                  file=sys.stderr)
            return 1
        if probs:
            print(f"VERIFY FAILED: sections differ: {probs}", file=sys.stderr)
            return 1
        print("verify: bit-identical round trip")
    return 0


if __name__ == "__main__":
    sys.exit(main())
