"""Migrate SAGe containers between on-disk layouts.

v1 (monolithic ``.npz``, whole-file decompress on every open) -> v2
(block-extent container: header + one alignment-padded extent per block,
lazy ranged reads — see DESIGN.md §7), and back for compatibility.

  PYTHONPATH=src python tools/migrate_container.py reads.sage.npz reads.sage2
  PYTHONPATH=src python tools/migrate_container.py reads.sage2 back.sage.npz --to-v1
  PYTHONPATH=src python tools/migrate_container.py in out --verify  # bit-identity

``--verify`` re-opens the migrated container, materializes it, and diffs
every section (meta, directory, consensus, all 14 streams) against the
source — exits non-zero on any mismatch. On v2 output this drives the full
checksum layer (header CRCs, per-extent CRC32C, commit footer), so a
corrupted or torn output also fails verify, printing the failing section.

``--legacy`` writes the pre-checksum v2 layout (no CRC section, no commit
footer) — for readers that predate the integrity format.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.errors import SageIOError  # noqa: E402
from repro.core.format import SageFile  # noqa: E402
from repro.core.layout import (  # noqa: E402
    DEFAULT_ALIGN,
    SageContainerV2,
    container_version,
    write_v2,
)


def _load_any(path: str) -> SageFile:
    if container_version(path) == 2:
        return SageContainerV2.open(path).to_sage_file()
    return SageFile.load(path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("src", help="source container (v1 .npz or v2)")
    ap.add_argument("dst", help="destination path")
    ap.add_argument("--to-v1", action="store_true",
                    help="write a v1 .npz instead of a v2 block-extent container")
    ap.add_argument("--align", type=int, default=DEFAULT_ALIGN,
                    help=f"v2 extent alignment in bytes (default {DEFAULT_ALIGN})")
    ap.add_argument("--verify", action="store_true",
                    help="re-open the output and check section-by-section bit-identity "
                         "(on v2 output this also runs the checksum layer)")
    ap.add_argument("--legacy", action="store_true",
                    help="write the pre-checksum v2 layout (no CRCs, no commit footer)")
    args = ap.parse_args(argv)

    sf = _load_any(args.src)
    if args.to_v1:
        sf.save(args.dst)
        print(f"v1 <- {args.src}: {sf.meta.n_blocks} blocks, "
              f"{os.path.getsize(args.dst)/1e6:.2f} MB -> {args.dst}")
    else:
        stats = write_v2(sf, args.dst, align=args.align,
                         integrity=not args.legacy)
        print(f"v2 <- {args.src}: {stats['n_blocks']} blocks x "
              f"{stats['stride_nbytes']} B extents (payload {stats['payload_nbytes']} B), "
              f"header {stats['header_nbytes']/1e3:.1f} KB"
              f"{' (legacy, unchecksummed)' if args.legacy else ''}, "
              f"total {stats['file_nbytes']/1e6:.2f} MB -> {args.dst}")

    if args.verify:
        try:
            probs = _load_any(args.dst).diff(sf)
        except SageIOError as e:
            section = e.section or "unknown section"
            print(f"VERIFY FAILED: {type(e).__name__} in {section}: {e}",
                  file=sys.stderr)
            return 1
        if probs:
            print(f"VERIFY FAILED: sections differ: {probs}", file=sys.stderr)
            return 1
        print("verify: bit-identical round trip")
    return 0


if __name__ == "__main__":
    sys.exit(main())
